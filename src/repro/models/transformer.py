"""Unified decoder stack for every assigned architecture.

The layer stack is a ``lax.scan`` over *super-layers* (the repeating block
pattern from ``ArchConfig.superlayer_pattern``), with parameters stacked on
the leading axis — HLO size is independent of depth, which is what makes the
95/126-layer dry-runs compile fast. Hybrid stacks (zamba2) additionally have
a non-scanned tail and a parameter-shared attention block closed over by the
scan body.

Three entry points:
  ``forward``      — logits for training (and prefill cache collection)
  ``prefill``      — forward + per-layer decode caches
  ``decode_step``  — one token, cache update (serving)

Parameters are plain nested dicts; ``params_shape`` produces the
ShapeDtypeStruct twin via ``jax.eval_shape`` so 405B-parameter dry-runs never
allocate.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import execution as ex
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.layers import (
    DEFAULT_RT, RuntimeCfg, _init, dense, embed_tokens, init_attn, init_mlp,
    lm_logits, rms_norm, swiglu_mlp,
)

Params = Dict[str, Any]

# Block kinds whose decode KV cache moves into the paged pool. ``attn_local``
# keeps its rolling-window buffer (already O(window), paging buys nothing)
# and SSM/linear-attention state stays slot-indexed (constant size per slot —
# the allocator accounts it as a "state block", core/paging.py).
PAGED_KINDS = ("attn_dense", "attn_global", "attn_moe", "shared_attn")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(kind: str, key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn_dense", "attn_local", "attn_global"):
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "attn": init_attn(k1, cfg, dtype),
                "norm2": jnp.zeros((d,), jnp.float32),
                "mlp": init_mlp(k2, cfg, dtype)}
    if kind == "attn_moe":
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "attn": init_attn(k1, cfg, dtype),
                "norm2": jnp.zeros((d,), jnp.float32),
                "moe": moe_mod.init_moe(k2, cfg, dtype)}
    if kind == "mamba2":
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "mamba": m2.init_mamba2(k1, cfg, dtype)}
    if kind == "rwkv6":
        return {"norm1": jnp.zeros((d,), jnp.float32),
                "norm2": jnp.zeros((d,), jnp.float32),
                "rwkv": rk.init_rwkv6(k1, cfg, dtype)}
    if kind == "shared_attn":
        return {}                      # params live in params["shared_attn"]
    raise ValueError(kind)


def _init_superlayer(key, cfg: ArchConfig, dtype) -> Params:
    pat = cfg.superlayer_pattern
    keys = jax.random.split(key, len(pat))
    return {f"b{i}": _init_block(kind, keys[i], cfg, dtype)
            for i, kind in enumerate(pat)}


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    d, vp = cfg.d_model, cfg.padded_vocab
    k_embed, k_head, k_layers, k_shared, k_tail = jax.random.split(key, 5)

    n_super = cfg.num_superlayers
    layer_keys = jax.random.split(k_layers, n_super)
    layers = jax.vmap(lambda k: _init_superlayer(k, cfg, dtype))(layer_keys)

    params: Params = {
        "embed": _init(k_embed, (vp, d), dtype, scale=1.0),
        "head": _init(k_head, (d, vp), dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "layers": layers,
    }
    if "shared_attn" in cfg.superlayer_pattern:
        ks1, ks2 = jax.random.split(k_shared)
        params["shared_attn"] = {
            "norm1": jnp.zeros((d,), jnp.float32),
            "attn": init_attn(ks1, cfg, dtype),
            "norm2": jnp.zeros((d,), jnp.float32),
            "mlp": init_mlp(ks2, cfg, dtype),
        }
    n_tail = cfg.hybrid_tail_layers
    if n_tail:
        tail_keys = jax.random.split(k_tail, n_tail)
        params["tail"] = jax.vmap(
            lambda k: _init_block("mamba2", k, cfg, dtype))(tail_keys)
    return params


def params_shape(cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct twin of ``init_params`` — no allocation."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Block application (training / prefill)
# ---------------------------------------------------------------------------

def _apply_block(kind: str, x, p: Params, cfg: ArchConfig, rt: RuntimeCfg,
                 shared: Optional[Params], collect_cache: bool):
    """Returns (x, aux, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind == "shared_attn":
        p = shared
    window = cfg.window_size if kind == "attn_local" else 0

    if kind in ("attn_dense", "attn_local", "attn_global", "attn_moe",
                "shared_attn"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if collect_cache:
            a, (k, v) = attn_mod.attention_block(
                h, p["attn"], cfg, rt, window=window, return_kv=True)
            cache = _kv_to_cache(k, v, window)
        else:
            a = attn_mod.attention_block(h, p["attn"], cfg, rt, window=window)
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            mo, aux = moe_mod.moe_mlp(h, p["moe"], cfg, rt)
            x = x + mo
        else:
            x = x + swiglu_mlp(h, p["mlp"], cfg, rt)
        return x, aux, cache

    if kind == "mamba2":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if collect_cache:
            o, (hs, conv) = m2.mamba2_block_with_state(h, p["mamba"], cfg, rt)
            cache = {"h": hs, "conv": conv}
        else:
            o = m2.mamba2_block(h, p["mamba"], cfg, rt)
        return x + o, aux, cache

    if kind == "rwkv6":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if collect_cache:
            o, (S, prev_tm) = rk.rwkv6_block_with_state(h, p["rwkv"], cfg, rt)
        else:
            o = rk.rwkv6_block(h, p["rwkv"], cfg, rt)
        x = x + o
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + rk.rwkv6_channel_mix(h2, p["rwkv"], cfg, rt)
        if collect_cache:
            cache = {"S": S, "prev_tm": prev_tm, "prev_cm": h2[:, -1:, :]}
        return x, aux, cache

    raise ValueError(kind)


def _kv_to_cache(k: jax.Array, v: jax.Array, window: int) -> Params:
    """Build a decode cache from prefill K/V (B, S, kv, hd).

    The ``pos`` buffer is per-sequence (B, S): continuous-batching slots
    advance independently, so each row tracks its own written positions.
    """
    b, s, kvh, hd = k.shape
    if not window or s < window:
        pos = jnp.arange(s, dtype=jnp.int32)
        return {"k": k, "v": v,
                "pos": jnp.broadcast_to(pos, (b, s))}
    # rolling window cache: slot j holds the token p in [s-window, s) with
    # p % window == j (so decode can keep writing at pos % window).
    p = jnp.arange(s - window, s, dtype=jnp.int32)
    slots = p % window
    kc = jnp.zeros((b, window, kvh, hd), k.dtype).at[:, slots].set(
        k[:, s - window:])
    vc = jnp.zeros((b, window, kvh, hd), v.dtype).at[:, slots].set(
        v[:, s - window:])
    posc = jnp.zeros((window,), jnp.int32).at[slots].set(p)
    return {"k": kc, "v": vc, "pos": jnp.broadcast_to(posc, (b, window))}


# ---------------------------------------------------------------------------
# Forward / prefill
# ---------------------------------------------------------------------------

def _superlayer_fn(cfg: ArchConfig, rt: RuntimeCfg, shared: Optional[Params],
                   collect_cache: bool):
    pat = cfg.superlayer_pattern

    def body(x, p_super):
        aux_total = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(pat):
            x, aux, cache = _apply_block(kind, x, p_super[f"b{i}"], cfg, rt,
                                         shared, collect_cache)
            aux_total = aux_total + aux
            if collect_cache:
                caches[f"b{i}"] = cache if cache is not None else {}
        return x, (aux_total, caches) if collect_cache else (aux_total, {})
    return body


def _run_stack(params: Params, x: jax.Array, cfg: ArchConfig, rt: RuntimeCfg,
               collect_cache: bool):
    shared = params.get("shared_attn")
    body = _superlayer_fn(cfg, rt, shared, collect_cache)

    if cfg.remat == "full":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    from repro.models.layers import shard_tag

    def scan_body(carry, p_super):
        x, aux = carry
        x = shard_tag(rt, x, "act_btd")      # re-anchor GSPMD each superlayer
        x, (aux_i, caches) = body(x, p_super)
        return (x, aux + aux_i), caches

    (x, aux), caches = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), params["layers"])

    tail_caches = None
    if "tail" in params:
        n_tail = cfg.hybrid_tail_layers
        tail_caches = []
        for i in range(n_tail):
            p_i = jax.tree.map(lambda a: a[i], params["tail"])
            x, _, c = _apply_block("mamba2", x, p_i, cfg, rt, None,
                                   collect_cache)
            tail_caches.append(c if c is not None else {})
        if collect_cache:
            tail_caches = jax.tree.map(
                lambda *xs: jnp.stack(xs), *tail_caches) if tail_caches else {}
    return x, aux, caches, tail_caches


def forward(params: Params, inputs: jax.Array, cfg: ArchConfig,
            rt: RuntimeCfg = DEFAULT_RT) -> Tuple[jax.Array, jax.Array]:
    """inputs: (B, S) int tokens or (B, S, d) embeddings.
    Returns (logits (B, S, Vp) f32, aux_loss)."""
    x, aux = forward_hidden(params, inputs, cfg, rt)
    logits = lm_logits(x, params["head"], cfg.vocab_size,
                       policy=ex.policy_from(cfg, rt))
    return logits, aux


def forward_hidden(params: Params, inputs: jax.Array, cfg: ArchConfig,
                   rt: RuntimeCfg = DEFAULT_RT) -> Tuple[jax.Array, jax.Array]:
    """Backbone only: final normed hidden (B, S, d) + aux. The train loss
    fuses the LM head per-chunk (runtime/train_loop.py) so the full f32
    (B, S, V) logits tensor is never materialized."""
    if inputs.ndim == 2:
        x = embed_tokens(inputs, params["embed"]).astype(rt.act_dtype)
    else:
        x = inputs.astype(rt.act_dtype)
    x, aux, _, _ = _run_stack(params, x, cfg, rt, collect_cache=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def prefill(params: Params, inputs: jax.Array, cfg: ArchConfig,
            rt: RuntimeCfg = DEFAULT_RT):
    """Returns (last_token_logits (B, Vp), caches)."""
    if inputs.ndim == 2:
        x = embed_tokens(inputs, params["embed"]).astype(rt.act_dtype)
    else:
        x = inputs.astype(rt.act_dtype)
    x, _, caches, tail_caches = _run_stack(params, x, cfg, rt,
                                           collect_cache=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x[:, -1], params["head"], cfg.vocab_size,
                       policy=ex.policy_from(cfg, rt))
    out_caches = {"layers": caches}
    if tail_caches is not None:
        out_caches["tail"] = tail_caches
    return logits, out_caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _decode_block(kind: str, x, p: Params, cache: Params, pos,
                  cfg: ArchConfig, rt: RuntimeCfg, shared: Optional[Params],
                  page_map=None):
    """Returns (x, new_cache). With ``page_map`` (B, max_pages), the
    PAGED_KINDS blocks read/write the pooled paged cache instead of the
    dense per-slot one."""
    if kind == "shared_attn":
        p = shared
    window = cfg.window_size if kind == "attn_local" else 0

    if kind in ("attn_dense", "attn_local", "attn_global", "attn_moe",
                "shared_attn"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if page_map is not None and kind in PAGED_KINDS:
            a, new_kv = _paged_decode_attn(h, p["attn"], cache, pos,
                                           page_map, cfg, rt)
        else:
            a, new_kv = _decode_attn(h, p["attn"], cache, pos, cfg, rt,
                                     window)
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            mo, _ = moe_mod.moe_mlp(h, p["moe"], cfg, rt)
            x = x + mo
        else:
            x = x + swiglu_mlp(h, p["mlp"], cfg, rt)
        return x, new_kv

    if kind == "mamba2":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        o, (hs, conv) = m2.mamba2_decode(h, p["mamba"], cfg,
                                         (cache["h"], cache["conv"]), rt)
        return x + o, {"h": hs, "conv": conv}

    if kind == "rwkv6":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        o, (S, prev_tm) = rk.rwkv6_decode(h, p["rwkv"], cfg,
                                          (cache["S"], cache["prev_tm"]), rt)
        x = x + o
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        o2, prev_cm = rk.rwkv6_channel_mix_decode(h2, p["rwkv"], cfg,
                                                  cache["prev_cm"], rt)
        x = x + o2
        return x, {"S": S, "prev_tm": prev_tm, "prev_cm": prev_cm}

    raise ValueError(kind)


def _decode_attn(x, p, cache, pos, cfg: ArchConfig, rt: RuntimeCfg,
                 window: int):
    from repro.models.layers import batched_einsum, shard_tag
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    # ``pos`` may be a scalar (lockstep decode) or (B,) — continuous
    # batching tracks an independent position per slot.
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = dense(x, p["w_q"], cfg, rt, "q").reshape(b, 1, h, hd)
    k = dense(x, p["w_k"], cfg, rt, "k").reshape(b, 1, kvh, hd)
    v = dense(x, p["w_v"], cfg, rt, "v").reshape(b, 1, kvh, hd)
    q = attn_mod.apply_rope(q, posb[:, None], cfg.rope_theta)
    k = attn_mod.apply_rope(k, posb[:, None], cfg.rope_theta)
    # flash-decoding sharding: q is tiny — replicate it over "model" so the
    # seq-sharded cache is contracted IN PLACE (partial scores + psum of the
    # (b, h, hd) output) instead of GSPMD all-gathering the whole cache to
    # match head-sharded q (measured: 2×1 GiB/layer on llama3-405b).
    q = shard_tag(rt, q, "decode_q")

    kc, vc, posc = cache["k"], cache["v"], cache["pos"]
    smax = kc.shape[1]
    slot = posb % smax if window else posb              # (b,) write rows
    bidx = jnp.arange(b)
    kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
    vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
    posc = posc.at[bidx, slot].set(posb)

    scale = hd ** -0.5
    # GQA kept grouped: (b, 1, kv, g, hd) × (b, s, kv, hd) — no broadcast
    # materialization of the expanded cache, no f32 operand upcast.
    q5 = q.reshape(b, kvh, g, hd)
    s = batched_einsum("bkgd,bskd->bkgs", q5, kc, rt,
                       out_dtype=jnp.float32) * scale     # (b, kv, g, s)
    # posc=-1 marks unwritten (or freed-slot) rows; each slot only attends
    # to rows its own occupant wrote at positions <= its own pos.
    valid = (posc >= 0) & (posc <= posb[:, None])        # (b, smax)
    if window:
        valid &= posc > posb[:, None] - window
    else:
        valid &= jnp.arange(smax)[None, :] <= posb[:, None]
    s = jnp.where(valid[:, None, None, :], s, attn_mod.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = batched_einsum("bkgs,bskd->bkgd", pr.astype(vc.dtype), vc, rt,
                       out_dtype=jnp.float32)
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    out = dense(o, p["w_o"], cfg, rt, "o")
    return out, {"k": kc, "v": vc, "pos": posc}


def _paged_decode_attn(x, p, cache, pos, page_map, cfg: ArchConfig,
                       rt: RuntimeCfg):
    """Decode attention over the pooled paged cache.

    ``cache`` leaves are pools: k/v ``(n_pages+1, page_size, kvh, hd)``,
    pos ``(n_pages+1, page_size)``; ``page_map`` is ``(B, max_pages)``
    int32 (``-1`` = unallocated). The last physical page is a *trash*
    page owned by no slot: writes for slots whose current page entry is
    ``-1`` (idle slots) land there, and gathers of unallocated logical
    pages read from it — its rows are never attended to because an
    unallocated logical page's row indices all exceed the slot's ``pos``
    (tables are prefixes, core/paging.py) and the causal ``arange <=
    pos`` mask kills them.

    Exactness contract: the gather reconstructs each slot's KV in the
    *identical* ``(B, max_len, ...)`` layout the dense path uses (row i
    holds position i; ``max_pages * page_size == max_len``), then runs
    the *same* mask/softmax/einsum code — masked rows are the same
    NEG_INF constant in both, their softmax weight underflows to exactly
    0.0, and 0 × finite garbage is 0, so paged greedy decode is
    token-for-token identical to dense.
    """
    from repro.models.layers import batched_einsum, shard_tag
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = dense(x, p["w_q"], cfg, rt, "q").reshape(b, 1, h, hd)
    k = dense(x, p["w_k"], cfg, rt, "k").reshape(b, 1, kvh, hd)
    v = dense(x, p["w_v"], cfg, rt, "v").reshape(b, 1, kvh, hd)
    q = attn_mod.apply_rope(q, posb[:, None], cfg.rope_theta)
    k = attn_mod.apply_rope(k, posb[:, None], cfg.rope_theta)
    q = shard_tag(rt, q, "decode_q")

    kp, vc_pool, pp = cache["k"], cache["v"], cache["pos"]
    ps = kp.shape[1]
    mp = page_map.shape[1]
    trash = kp.shape[0] - 1
    page_map = jnp.asarray(page_map, jnp.int32)

    # write the current token at (physical page, in-page offset); idle
    # slots (entry -1) are routed to the trash page so live pages are
    # never aliased.
    lpage = jnp.clip(posb // ps, 0, mp - 1)
    off = posb % ps
    phys = jnp.take_along_axis(page_map, lpage[:, None], axis=1)[:, 0]
    # positions at/past the table's capacity (mp * ps == max_len) must not
    # alias the clipped last page — the dense path's scatter drops such
    # out-of-bounds rows, so the paged path routes them to trash. Plain
    # decode never reaches here (the host finishes a slot at max_len), but
    # a k>1 speculative verify legitimately probes a few positions past
    # the end of an almost-full slot.
    phys = jnp.where((phys >= 0) & (posb < mp * ps), phys, trash)
    kp = kp.at[phys, off].set(k[:, 0].astype(kp.dtype))
    vc_pool = vc_pool.at[phys, off].set(v[:, 0].astype(vc_pool.dtype))
    pp = pp.at[phys, off].set(posb)

    # gather back into the dense (b, max_len, ...) layout
    safe = jnp.where(page_map >= 0, page_map, trash)       # (b, mp)
    kc = kp[safe].reshape(b, mp * ps, kvh, hd)
    vc = vc_pool[safe].reshape(b, mp * ps, kvh, hd)
    posc = pp[safe].reshape(b, mp * ps)
    smax = mp * ps

    # from here: byte-identical to the dense _decode_attn arithmetic
    scale = hd ** -0.5
    q5 = q.reshape(b, kvh, g, hd)
    s = batched_einsum("bkgd,bskd->bkgs", q5, kc, rt,
                       out_dtype=jnp.float32) * scale
    valid = (posc >= 0) & (posc <= posb[:, None])
    valid &= jnp.arange(smax)[None, :] <= posb[:, None]
    s = jnp.where(valid[:, None, None, :], s, attn_mod.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = batched_einsum("bkgs,bskd->bkgd", pr.astype(vc.dtype), vc, rt,
                       out_dtype=jnp.float32)
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    out = dense(o, p["w_o"], cfg, rt, "o")
    return out, {"k": kp, "v": vc_pool, "pos": pp}


def decode_step(params: Params, tokens: jax.Array, caches: Params, pos,
                cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT):
    """One decoding step. tokens: (B, 1) int32; pos: scalar int32 (lockstep
    — same position for all sequences) or (B,) int32 (continuous batching —
    each slot decodes at its own position; see runtime/serve_loop.py).
    Returns (logits (B, Vp) f32, new_caches)."""
    x = embed_tokens(tokens, params["embed"]).astype(rt.act_dtype)
    shared = params.get("shared_attn")
    pat = cfg.superlayer_pattern

    from repro.models.layers import shard_tag

    def scan_body(carry, inp):
        x = carry
        p_super, cache_super = inp
        x = shard_tag(rt, x, "act_btd")
        new_caches = {}
        for i, kind in enumerate(pat):
            x, nc = _decode_block(kind, x, p_super[f"b{i}"],
                                  cache_super[f"b{i}"], pos, cfg, rt, shared)
            new_caches[f"b{i}"] = nc
        return x, new_caches

    x, new_layer_caches = jax.lax.scan(
        scan_body, x, (params["layers"], caches["layers"]))

    new_caches = {"layers": new_layer_caches}
    if "tail" in params:
        n_tail = cfg.hybrid_tail_layers
        tails = []
        for i in range(n_tail):
            p_i = jax.tree.map(lambda a: a[i], params["tail"])
            c_i = jax.tree.map(lambda a: a[i], caches["tail"])
            x, nc = _decode_block("mamba2", x, p_i, c_i, pos, cfg, rt, None)
            tails.append(nc)
        new_caches["tail"] = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x[:, 0], params["head"], cfg.vocab_size,
                       policy=ex.policy_from(cfg, rt))
    return logits, new_caches


def paged_decode_step(params: Params, tokens: jax.Array, caches: Params,
                      pos, page_map: jax.Array, cfg: ArchConfig,
                      rt: RuntimeCfg = DEFAULT_RT):
    """``decode_step`` over a paged cache (``init_paged_cache`` layout).

    ``page_map`` (B, max_pages) int32 is shared by every layer — one
    physical page id names the same rows in each layer's pool — so it is
    closed over by the scan body rather than scanned. Tail blocks and
    non-PAGED_KINDS leaves behave exactly as in ``decode_step``."""
    x = embed_tokens(tokens, params["embed"]).astype(rt.act_dtype)
    shared = params.get("shared_attn")
    pat = cfg.superlayer_pattern

    from repro.models.layers import shard_tag

    def scan_body(carry, inp):
        x = carry
        p_super, cache_super = inp
        x = shard_tag(rt, x, "act_btd")
        new_caches = {}
        for i, kind in enumerate(pat):
            x, nc = _decode_block(kind, x, p_super[f"b{i}"],
                                  cache_super[f"b{i}"], pos, cfg, rt,
                                  shared, page_map=page_map)
            new_caches[f"b{i}"] = nc
        return x, new_caches

    x, new_layer_caches = jax.lax.scan(
        scan_body, x, (params["layers"], caches["layers"]))

    new_caches = {"layers": new_layer_caches}
    if "tail" in params:
        n_tail = cfg.hybrid_tail_layers
        tails = []
        for i in range(n_tail):
            p_i = jax.tree.map(lambda a: a[i], params["tail"])
            c_i = jax.tree.map(lambda a: a[i], caches["tail"])
            x, nc = _decode_block("mamba2", x, p_i, c_i, pos, cfg, rt, None)
            tails.append(nc)
        new_caches["tail"] = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(x[:, 0], params["head"], cfg.vocab_size,
                       policy=ex.policy_from(cfg, rt))
    return logits, new_caches


# ---------------------------------------------------------------------------
# Speculative multi-token verify (draft-and-verify decode; core/speculative)
# ---------------------------------------------------------------------------

def _rollback_caches(snaps, n_acc, posb, cfg: ArchConfig, page_map=None):
    """Select the committed cache after a k-step verify pass.

    ``snaps[j]`` is the full cache tree after verify step ``j``, so
    ``snaps[n_acc[i]]`` is slot ``i``'s last *committed* state. Rather
    than replay, the rollback treats the two cache-leaf classes
    differently:

    * **append leaves** — k/v/pos of the ``PAGED_KINDS`` attention
      caches. Row (or page offset) ``posb + j`` holds only step ``j``'s
      write, so the final snapshot is kept and rejected rows
      ``> posb + n_acc`` are scrubbed back to the init sentinel (pos
      ``-1``, k/v ``0``) — identical to what an unwritten row holds, so
      over-scrubbing rows that were never written is a value no-op.
    * **state leaves** — rolling-window KV (``attn_local``), mamba2 /
      rwkv6 recurrent state, and the hybrid tail. Steps overwrite these
      in place (a rejected write destroys history that masking cannot
      recover), so the per-step snapshots are stacked on a new leading
      axis and each slot gathers the snapshot at its accepted count.

    The stack materializes append leaves too, but those stacked copies
    are never consumed, so XLA dead-code-eliminates them under jit.
    """
    k = len(snaps)
    final = snaps[-1]
    b = posb.shape[0]
    append_blocks = {f"b{i}" for i, kind in enumerate(cfg.superlayer_pattern)
                     if kind in PAGED_KINDS}
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *snaps)

    def fix(path, f, st):
        keys = [str(getattr(p, "key", p)) for p in path]
        if (keys[0] == "layers" and keys[1] in append_blocks
                and keys[-1] in ("k", "v", "pos")):
            zero = jnp.asarray(-1 if keys[-1] == "pos" else 0, f.dtype)
            if page_map is None:
                # dense layout (n_super, B, max_len, ...): mask-scrub the
                # rejected rows (row index == position).
                smax = f.shape[2]
                scrub = jnp.arange(smax, dtype=jnp.int32)[None, :] \
                    > (posb + n_acc)[:, None]                    # (B, smax)
                scrub = scrub.reshape((1, b, smax) + (1,) * (f.ndim - 3))
                return jnp.where(scrub, zero, f)
            # pooled layout (n_super, pages+1, page_size, ...): scatter-
            # scrub each rejected step's (page, offset) row. Accepted
            # steps and unmapped/out-of-range positions are redirected to
            # the trash page (duplicate trash writes are fine — the
            # scrubbed value is a constant).
            ps = f.shape[2]
            mp = page_map.shape[1]
            trash = f.shape[1] - 1
            for j in range(1, k):
                pj = posb + j
                lpage = jnp.clip(pj // ps, 0, mp - 1)
                off = pj % ps
                phys = jnp.take_along_axis(page_map, lpage[:, None],
                                           axis=1)[:, 0]
                phys = jnp.where((phys >= 0) & (pj < mp * ps), phys, trash)
                phys = jnp.where(j > n_acc, phys, trash)
                f = f.at[:, phys, off].set(zero)
            return f
        # state leaf: stacked (k, n_axis, B, ...) -> per-slot snapshot
        moved = jnp.moveaxis(st, 2, 0)                       # (B, k, n, ...)
        idx = n_acc.reshape((b, 1) + (1,) * (moved.ndim - 2))
        idx = jnp.broadcast_to(idx, (b, 1) + moved.shape[2:])
        sel = jnp.take_along_axis(moved, idx, axis=1)[:, 0]  # (B, n, ...)
        return jnp.moveaxis(sel, 0, 1)

    return jax.tree_util.tree_map_with_path(fix, final, stacked)


def _multi_decode(params: Params, tokens_seq: jax.Array, caches: Params,
                  pos, active, cfg: ArchConfig, rt: RuntimeCfg,
                  page_map=None):
    b, k = tokens_seq.shape
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    cur = caches
    snaps = []
    greedy = []
    for j in range(k):
        tok = tokens_seq[:, j:j + 1].astype(jnp.int32)
        if page_map is None:
            logits, cur = decode_step(params, tok, cur, posb + j, cfg, rt)
        else:
            logits, cur = paged_decode_step(params, tok, cur, posb + j,
                                            page_map, cfg, rt)
        greedy.append(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        snaps.append(cur)
    g = jnp.stack(greedy, axis=1)                            # (B, k)
    if k == 1:
        return g[:, 0:1], g, jnp.zeros((b,), jnp.int32), cur
    match = (tokens_seq[:, 1:].astype(jnp.int32) == g[:, :-1])
    n_acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    # idle (free) slots must behave like plain decode — exactly one write
    # at their parked position, which admission overwrites — so drafts
    # are never accepted for them.
    n_acc = jnp.where(jnp.asarray(active, bool), n_acc, 0)
    next_tok = jnp.take_along_axis(g, n_acc[:, None], axis=1)
    new_caches = _rollback_caches(snaps, n_acc, posb, cfg, page_map=page_map)
    return next_tok, g, n_acc, new_caches


def multi_decode_step(params: Params, tokens_seq: jax.Array, caches: Params,
                      pos, active, cfg: ArchConfig,
                      rt: RuntimeCfg = DEFAULT_RT):
    """Score k candidate tokens in ONE jitted pass (speculative verify).

    ``tokens_seq`` (B, k) carries each slot's next input token followed
    by k-1 draft tokens; ``pos`` (B,) is each slot's decode position and
    ``active`` (B,) bool marks occupied slots. Step ``j`` runs the exact
    ``decode_step`` computation at ``pos + j``, so its argmax ``g[:, j]``
    is *precisely* what plain greedy decode would emit after committing
    the first ``j`` candidates. The accepted count ``n_acc`` is the
    longest prefix of drafts matching those argmaxes, which makes the
    committed tokens ``g[:, :n_acc+1]`` provably identical to plain
    greedy decode — the exactness contract speculative serving pins.

    Returns ``(next_tokens (B, 1), greedy (B, k), n_acc (B,),
    new_caches)`` with rejected-token cache writes rolled back
    (:func:`_rollback_caches`)."""
    return _multi_decode(params, tokens_seq, caches, pos, active, cfg, rt)


def paged_multi_decode_step(params: Params, tokens_seq: jax.Array,
                            caches: Params, pos, active,
                            page_map: jax.Array, cfg: ArchConfig,
                            rt: RuntimeCfg = DEFAULT_RT):
    """``multi_decode_step`` over a paged cache: rejected pool writes are
    scrubbed in-jit, so the allocator can release over-grown pages
    afterwards without touching device memory (``PageAllocator.
    trim_slot``)."""
    return _multi_decode(params, tokens_seq, caches, pos, active, cfg, rt,
                         page_map=page_map)


# ---------------------------------------------------------------------------
# Cache init (zeros / shape-only)
# ---------------------------------------------------------------------------

def _block_cache(kind: str, batch: int, max_len: int, cfg: ArchConfig,
                 dtype=jnp.bfloat16):
    if kind in ("attn_dense", "attn_global", "attn_moe", "shared_attn"):
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, max_len, kvh, hd), dtype),
                "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
                "pos": jnp.full((batch, max_len), -1, jnp.int32)}
    if kind == "attn_local":
        w = min(cfg.window_size, max_len)
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, w, kvh, hd), dtype),
                "v": jnp.zeros((batch, w, kvh, hd), dtype),
                "pos": jnp.full((batch, w), -1, jnp.int32)}
    if kind == "mamba2":
        h, conv = m2.init_mamba2_state(batch, cfg)
        return {"h": h, "conv": conv}
    if kind == "rwkv6":
        d = cfg.d_model
        nh = d // cfg.ssm_head_dim
        return {"S": jnp.zeros((batch, nh, cfg.ssm_head_dim,
                                cfg.ssm_head_dim), jnp.float32),
                "prev_tm": jnp.zeros((batch, 1, d), dtype),
                "prev_cm": jnp.zeros((batch, 1, d), dtype)}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    pat = cfg.superlayer_pattern
    n_super = cfg.num_superlayers

    def one_super():
        return {f"b{i}": _block_cache(kind, batch, max_len, cfg, dtype)
                for i, kind in enumerate(pat)}

    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(), one_super())
    caches = {"layers": stacked}
    n_tail = cfg.hybrid_tail_layers
    if n_tail:
        tail = _block_cache("mamba2", batch, max_len, cfg, dtype)
        caches["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_tail,) + a.shape).copy(), tail)
    return caches


def cache_shape(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Params:
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def init_paged_cache(cfg: ArchConfig, batch: int, max_len: int,
                     page_size: int, pages: int,
                     dtype=jnp.bfloat16) -> Params:
    """Paged twin of ``init_cache``: PAGED_KINDS leaves become pools of
    ``pages + 1`` physical pages (the extra one is the trash page, see
    ``_paged_decode_attn``) of ``page_size`` rows each, shared by all
    slots; everything else (window caches, SSM state, tail) stays
    slot-indexed dense. Requires ``max_len % page_size == 0`` so the
    gathered layout matches the dense one row-for-row."""
    if max_len % page_size:
        raise ValueError(f"max_len={max_len} not a multiple of "
                         f"page_size={page_size}")
    pat = cfg.superlayer_pattern
    n_super = cfg.num_superlayers
    kvh, hd = cfg.num_kv_heads, cfg.head_dim

    def one_block(kind):
        if kind in PAGED_KINDS:
            p1 = pages + 1
            return {"k": jnp.zeros((p1, page_size, kvh, hd), dtype),
                    "v": jnp.zeros((p1, page_size, kvh, hd), dtype),
                    "pos": jnp.full((p1, page_size), -1, jnp.int32)}
        return _block_cache(kind, batch, max_len, cfg, dtype)

    one_super = {f"b{i}": one_block(kind) for i, kind in enumerate(pat)}
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(), one_super)
    caches = {"layers": stacked}
    n_tail = cfg.hybrid_tail_layers
    if n_tail:
        tail = _block_cache("mamba2", batch, max_len, cfg, dtype)
        caches["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_tail,) + a.shape).copy(), tail)
    return caches


# ---------------------------------------------------------------------------
# Standalone super-layer entry points (roofline per-layer cost lowering —
# cost_analysis counts scan bodies once, so launch/dryrun.py lowers ONE
# super-layer separately and scales; see launch/roofline.py).
# ---------------------------------------------------------------------------

def superlayer_params_slice(params_or_shapes: Params) -> Params:
    """First super-layer's (unstacked) params — works on shapes too."""
    def take0(a):
        if hasattr(a, "shape"):
            if isinstance(a, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
            return a[0]
        return a
    return jax.tree.map(take0, params_or_shapes["layers"])


def superlayer_forward(x: jax.Array, p_super: Params,
                       shared: Optional[Params], cfg: ArchConfig,
                       rt: RuntimeCfg):
    """One (possibly rematted) super-layer forward: x -> (x', aux)."""
    from repro.models.layers import shard_tag
    x = shard_tag(rt, x, "act_btd")          # same anchor as the scan body
    body = _superlayer_fn(cfg, rt, shared, collect_cache=False)
    if cfg.remat == "full":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, (aux, _) = body(x, p_super)
    return x, aux


def superlayer_train_cost(x: jax.Array, ct: jax.Array, p_super: Params,
                          shared: Optional[Params], cfg: ArchConfig,
                          rt: RuntimeCfg):
    """fwd+bwd of one super-layer (the per-layer train-cost probe).

    ``ct`` is the output cotangent; returns grads wrt (x, p_super, shared)."""
    def scalar(x, p_super, shared):
        y, aux = superlayer_forward(x, p_super, shared, cfg, rt)
        return jnp.sum(y.astype(jnp.float32) * ct.astype(jnp.float32)) + aux
    argnums = (0, 1) if shared is None else (0, 1, 2)
    return jax.grad(scalar, argnums=argnums)(x, p_super, shared)


def superlayer_decode(x: jax.Array, p_super: Params, cache_super: Params,
                      pos, shared: Optional[Params], cfg: ArchConfig,
                      rt: RuntimeCfg):
    """One decode super-layer step: (x, cache) -> (x', cache')."""
    pat = cfg.superlayer_pattern
    new_caches = {}
    for i, kind in enumerate(pat):
        x, nc = _decode_block(kind, x, p_super[f"b{i}"], cache_super[f"b{i}"],
                              pos, cfg, rt, shared)
        new_caches[f"b{i}"] = nc
    return x, new_caches


def superlayer_cache_slice(cache_or_shapes: Params) -> Params:
    def take0(a):
        if isinstance(a, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
        return a[0]
    return jax.tree.map(take0, cache_or_shapes["layers"])
