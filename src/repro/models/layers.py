"""Shared building blocks: norms, RoPE, the policy-routed linear, MLP.

The ``dense()`` primitive is the single place where the paper's two weight
techniques plug into every architecture. It resolves an
:class:`~repro.core.execution.ExecutionPolicy` (precision × sparsity ×
backend × block shapes) and dispatches through the matmul backend registry:

* ``precision="fp8"``   → tensor-scaled FP8 matmul, FP32 accumulation.
* ``sparsity="sparse24"`` → 2:4 magnitude pruning with straight-through
  estimator in training; packed weights (``PackedWeight``) in serving.
* ``backend`` picks ``ref``/``jnp``/``pallas``/``pallas_sparse24``.

All other call sites are ordinary bf16 matmuls with f32 accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import execution as ex
from repro.core import sparsity as sp
from repro.core.execution import PackedWeight, pack_weight  # re-export


# ---------------------------------------------------------------------------
# Runtime configuration (lowering/execution knobs, not architecture)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RuntimeCfg:
    """Execution knobs threaded through model forward functions."""
    chunk_q: int = 1024
    chunk_kv: int = 1024
    static_loops: bool = True     # python loops (exact HLO cost) vs lax.scan
    use_pallas: bool = False      # TPU kernels (validated in interpret mode)
    param_dtype: Any = jnp.bfloat16
    act_dtype: Any = jnp.bfloat16
    ssm_chunk: int = 256
    # static (python) ssm-chunk loops only up to this count — beyond it the
    # trace/compile cost explodes; lax.scan takes over and the dry-run adds
    # the per-chunk cost correction analytically (launch/dryrun.py).
    max_static_chunks: int = 64
    remat_blocks: bool = True     # jax.checkpoint around attention blocks
    # XLA:CPU cannot execute batched bf16×bf16→f32 dots (DotThunk limit);
    # True upcasts batched-dot operands to f32 for execution. The dry-run
    # lowers with False so the roofline sees the TPU contract (bf16 operands,
    # f32 accumulation in the MXU).
    f32_batched_dots: bool = True
    # Optional sharding-constraint hook: fn(tag, x) -> x (runtime/sharding.py
    # wires with_sharding_constraint specs by tag; None = rely on GSPMD
    # propagation from param/input shardings alone).
    shard_fn: Any = None
    # Beyond-paper (§Perf): gather/scatter MoE dispatch instead of the
    # GShard one-hot einsum — removes the O(T·gs·k·d) dispatch matmul FLOPs
    # (dominant for fine-grained-expert archs like granite).
    moe_gather_dispatch: bool = False
    # Explicit execution policy. When set it wins over cfg.precision /
    # cfg.sparsity_24 / use_pallas for every matmul routed through dense()
    # (see core/execution.policy_from).
    policy: Any = None


def shard_tag(rt: "RuntimeCfg", x, tag: str):
    if rt.shard_fn is None:
        return x
    return rt.shard_fn(tag, x)


# ---------------------------------------------------------------------------
# Differentiable scheduling barrier
# ---------------------------------------------------------------------------

@jax.custom_vjp
def opt_barrier(xs):
    """``jax.lax.optimization_barrier`` made differentiable.

    optimization_barrier_p has no AD rules on this JAX version, which
    breaks ``jax.grad`` over the chunked model loops. The barrier is a
    scheduling hint, so the VJP barriers the *cotangents* identically —
    the backward pass needs the same liveness bound as the forward (each
    chunk's backward temporaries sequence behind the cotangent carry).
    """
    return jax.lax.optimization_barrier(xs)


def _opt_barrier_fwd(xs):
    return jax.lax.optimization_barrier(xs), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


DEFAULT_RT = RuntimeCfg()


# ---------------------------------------------------------------------------
# The policy-routed linear
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_prune24(w: jax.Array) -> jax.Array:
    return sp.prune_24(w)


def _ste_fwd(w):
    return sp.prune_24(w), None


def _ste_bwd(_, g):
    return (g,)          # straight-through: gradient flows to all weights


_ste_prune24.defvjp(_ste_fwd, _ste_bwd)


def dense(x: jax.Array, w, cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
          name: str = "") -> jax.Array:
    """``x @ w`` routed through the resolved execution policy.

    ``w`` is a dense (K, N) array or a :class:`PackedWeight` (serving).
    The STE 2:4 prune (training form of sparsity) happens here — it must
    wrap the *differentiable* weight before the backend sees it; everything
    else is the registry's job.
    """
    pol = ex.policy_from(cfg, rt)
    if not isinstance(w, PackedWeight) and pol.sparsity == "sparse24" \
            and w.ndim == 2 and w.shape[0] % 8 == 0:
        w = _ste_prune24(w)
        if pol.backend == "pallas_sparse24":
            # the weight is already 2:4 with STE gradients; the backend's
            # dense entry would re-prune with *masked* gradients (and pay
            # the pack per call) — the plain pallas dense kernel computes
            # the identical product with STE-consistent dense grads
            pol = dataclasses.replace(pol, backend="pallas")
    return ex.matmul(x, w, pol, out_dtype=rt.act_dtype)


def batched_einsum(expr: str, a: jax.Array, b: jax.Array, rt: RuntimeCfg,
                   out_dtype=None) -> jax.Array:
    """Batched matmul with f32 accumulation, honoring rt.f32_batched_dots."""
    out_dtype = out_dtype or rt.act_dtype
    if rt.f32_batched_dots:
        acc = jnp.einsum(expr, a.astype(jnp.float32), b.astype(jnp.float32))
    else:
        acc = jnp.einsum(expr, a, b, preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Norms / RoPE / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, h, hd); positions: (S,) or broadcastable."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_tokens(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def lm_logits(h: jax.Array, head_w: jax.Array, vocab_size: int,
              policy: Any = None) -> jax.Array:
    """Project to (padded) vocab; mask padding logits to -inf.

    The head stays in the policy's *dense* path regardless of precision or
    sparsity (§9.2 mixed-precision guidance: keep the logit projection
    precise while expert/linear GEMMs run FP8/2:4) — including demoting
    ``pallas_sparse24``, whose dense entry would otherwise 2:4-prune the
    vocab projection on the fly."""
    pol = policy or ex.get_default_policy()
    backend = "pallas" if pol.backend == "pallas_sparse24" else pol.backend
    logits = ex.matmul(
        h, head_w,
        dataclasses.replace(pol, precision="bf16", sparsity="dense",
                            backend=backend),
        out_dtype=jnp.float32)
    vp = head_w.shape[-1]
    if vp != vocab_size:
        mask = jnp.arange(vp) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
               rt: RuntimeCfg = DEFAULT_RT) -> jax.Array:
    gate = dense(x, p["w_gate"], cfg, rt, "mlp_gate")
    up = dense(x, p["w_up"], cfg, rt, "mlp_up")
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return dense(h, p["w_down"], cfg, rt, "mlp_down")


# ---------------------------------------------------------------------------
# Parameter initializers (real arrays; shape-only twins live in transformer.py)
# ---------------------------------------------------------------------------

def _init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_mlp(key, cfg: ArchConfig, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (d, f), dtype),
        "w_up": _init(k2, (d, f), dtype),
        "w_down": _init(k3, (f, d), dtype),
    }


def init_attn(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w_q": _init(k1, (d, cfg.q_dim), dtype),
        "w_k": _init(k2, (d, cfg.kv_dim), dtype),
        "w_v": _init(k3, (d, cfg.kv_dim), dtype),
        "w_o": _init(k4, (cfg.q_dim, d), dtype),
    }
