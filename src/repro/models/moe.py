"""Mixture-of-Experts layer: top-k router + grouped capacity dispatch.

GShard/Switch-style einsum dispatch (pjit-friendly — experts shard on the
``model`` mesh axis when E divides it, per-expert ``d_ff`` shards otherwise;
see runtime/sharding.py). Tokens are processed in groups of
``cfg.moe_group_size`` so the dispatch one-hot stays O(T · gs · k · cf)
rather than O(T²k/E).

The router runs in f32 (paper §9.2 mixed-precision guidance: keep
precision-sensitive ops high while expert GEMMs run FP8/2:4).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    RuntimeCfg, DEFAULT_RT, batched_einsum, dense, shard_tag, swiglu_mlp,
    _init)


def capacity(cfg: ArchConfig, group_size: int) -> int:
    c = int(math.ceil(group_size * cfg.experts_top_k
                      * cfg.moe_capacity_factor / cfg.num_experts))
    return max(c, 1)


def router_dispatch(logits: jax.Array, cfg: ArchConfig,
                    cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing with capacity.

    logits: (G, gs, E) f32. Returns
      combine  (G, gs, E, C) f32 — softmax weight where routed, else 0,
      dispatch (G, gs, E, C) bool,
      aux      scalar load-balance loss (Switch aux).
    """
    G, gs, E = logits.shape
    k = cfg.experts_top_k
    gates = jax.nn.softmax(logits, axis=-1)                     # (G, gs, E)

    # top-k expert ids per token
    topv, topi = jax.lax.top_k(gates, k)                        # (G, gs, k)
    # normalize selected gate values (standard for k>1)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # one-hot per choice: (G, gs, k, E)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)

    # position in expert: priority = (choice-major, token-minor) — earlier
    # choices win capacity slots first (GShard convention).
    # flatten (k, gs) -> priority order, cumsum per expert.
    oh_kt = onehot.transpose(0, 2, 1, 3).reshape(G, k * gs, E)  # choice-major
    pos_flat = jnp.cumsum(oh_kt, axis=1) - oh_kt                # pos within expert
    pos = pos_flat.reshape(G, k, gs, E).transpose(0, 2, 1, 3)   # (G, gs, k, E)
    in_cap = (pos < cap) & (onehot > 0)

    # scatter into capacity slots: (G, gs, E, C)
    slot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    slot = slot * in_cap[..., None]                             # (G, gs, k, E, C)
    dispatch = slot.sum(axis=2) > 0                             # (G, gs, E, C)
    combine = (slot * topv[..., None, None] * onehot[..., None]).sum(axis=2)

    # Switch load-balance aux: E * mean(fraction routed)·mean(gate),
    # normalized by k so perfect balance gives 1.0 for any top-k
    frac = onehot.sum(axis=2).mean(axis=1) / k                  # (G, E)
    mean_gate = gates.mean(axis=1)                              # (G, E)
    aux = (frac * mean_gate).sum(axis=-1).mean() * E
    return combine.astype(jnp.float32), dispatch, aux


def gather_dispatch(logits: jax.Array, cfg: ArchConfig, cap: int):
    """Gather/scatter routing (beyond-paper §Perf): returns
    (token_idx (G,E,C) int32, weight (G,E,C) f32, aux).

    Equivalent routing decision to :func:`router_dispatch` but realized as a
    sort + gather instead of one-hot einsums — zero dispatch FLOPs. Priority
    is choice-major then token order, matching the einsum path.
    """
    G, gs, E = logits.shape
    k = cfg.experts_top_k
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # flat choices in choice-major priority order: index c*gs + s
    eid = topi.transpose(0, 2, 1).reshape(G, k * gs)        # (G, k*gs)
    wgt = topv.transpose(0, 2, 1).reshape(G, k * gs)
    order = jnp.argsort(eid, axis=1, stable=True)           # by expert, prio
    eid_sorted = jnp.take_along_axis(eid, order, axis=1)
    counts = jnp.sum(jax.nn.one_hot(eid, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts            # exclusive (G,E)
    slot_pos = starts[:, :, None] + jnp.arange(cap)[None, None]  # (G,E,C)
    valid = jnp.arange(cap)[None, None] < counts[:, :, None]
    slot_pos = jnp.clip(slot_pos, 0, k * gs - 1)
    flat_choice = jnp.take_along_axis(
        order, slot_pos.reshape(G, E * cap), axis=1)        # (G, E*C)
    token_idx = (flat_choice % gs).reshape(G, E, cap).astype(jnp.int32)
    weight = jnp.take_along_axis(wgt, flat_choice, axis=1) \
        .reshape(G, E, cap) * valid

    frac = jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32),
                   axis=2).mean(axis=1) / k
    aux = (frac * gates.mean(axis=1)).sum(axis=-1).mean() * E
    return token_idx, weight.astype(jnp.float32), aux


def moe_mlp(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
            rt: RuntimeCfg = DEFAULT_RT) -> Tuple[jax.Array, jax.Array]:
    """MoE feed-forward. x: (B, S, d) -> (out, aux_loss).

    Expert weights: p["w_gate"|"w_up"]: (E, d, f); p["w_down"]: (E, f, d);
    p["router"]: (d, E); optional p["shared"]: dense SwiGLU params.
    """
    b, s, d = x.shape
    E = cfg.num_experts
    gs = min(cfg.moe_group_size, b * s)
    T = b * s
    assert T % gs == 0, (T, gs)
    G = T // gs
    cap = capacity(cfg, gs)

    # token groups shard over every mesh axis (batch·seq product); the
    # dispatch einsum output then reshards to expert-parallel layout — GSPMD
    # emits the canonical MoE all-to-all between the two constraints.
    xt = shard_tag(rt, x.reshape(G, gs, d), "moe_tokens")
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))

    if rt.moe_gather_dispatch:
        token_idx, weight, aux = gather_dispatch(logits, cfg, cap)
        xin = jnp.take_along_axis(
            xt, token_idx.reshape(G, E * cap)[..., None], axis=1) \
            .reshape(G, E, cap, d)
    else:
        combine, dispatch, aux = router_dispatch(logits, cfg, cap)
        # dispatch tokens to expert capacity slots: (G, E, C, d)
        xin = batched_einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xt,
                             rt)
    xin = shard_tag(rt, xin, "moe_dispatch")

    # expert SwiGLU: (G, E, C, d) x (E, d, f)
    from repro.core import execution as ex
    pol = ex.policy_from(cfg, rt)

    def edot(a, w):
        """Per-expert matmul through the registry; FP8 applies per-expert
        dynamic scaling (one scale per expert weight, matching the paper's
        per-tensor recipe at expert granularity). bf16 experts also route
        per-expert when a Pallas backend is selected — otherwise the
        batched einsum IS the jnp backend and stays fused."""
        if pol.precision == "fp8" or pol.backend.startswith("pallas"):
            if rt.f32_batched_dots:
                # CPU execution: unrolled per-expert plain dots (supported)
                outs = [ex.matmul(a[:, e], w[e], pol, out_dtype=rt.act_dtype)
                        for e in range(w.shape[0])]
                return jnp.stack(outs, axis=1)
            return jax.vmap(lambda ai, wi: ex.matmul(
                ai, wi, pol, out_dtype=rt.act_dtype),
                in_axes=(1, 0), out_axes=1)(a, w)
        return batched_einsum("gecx,exf->gecf", a, w, rt)

    gate = edot(xin, p["w_gate"])
    up = edot(xin, p["w_up"])
    hmid = jax.nn.silu(gate.astype(jnp.float32)).astype(rt.act_dtype) * up
    down = edot(hmid, p["w_down"])

    # combine back: (G, gs, d)
    if rt.moe_gather_dispatch:
        contrib = (down.astype(jnp.float32)
                   * weight[..., None]).reshape(G, E * cap, d)
        gidx = jnp.arange(G)[:, None]
        out = jnp.zeros((G, gs, d), jnp.float32) \
            .at[gidx, token_idx.reshape(G, E * cap)].add(contrib) \
            .astype(x.dtype)
    else:
        out = batched_einsum("gsec,gecd->gsd", combine, down, rt,
                             out_dtype=x.dtype)
    out = out.reshape(b, s, d)

    if cfg.moe_shared_expert and "shared" in p:
        out = out + swiglu_mlp(x, p["shared"], cfg, rt)
    return out, aux.astype(jnp.float32)


def init_moe(key, cfg: ArchConfig, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _init(k1, (d, E), jnp.float32),
        "w_gate": _init(k2, (E, d, f), dtype),
        "w_up": _init(k3, (E, d, f), dtype),
        "w_down": _init(k4, (E, f, d), dtype),
    }
    if cfg.moe_shared_expert:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(k5, cfg, dtype)
    return p
