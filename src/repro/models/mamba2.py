"""Mamba2 (SSD) block — chunked parallel scan for training, O(1) decode.

Simplified single-group SSD following the Mamba2 formulation:
  h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_tᵀ          (state: (nh, hp, N))
  y_t = C_t h_t + D x_t

Chunked algorithm (chunk length Lc): intra-chunk term is a masked quadratic
attention-like product; inter-chunk term carries the state recurrence across
chunks (python loop when ``rt.static_loops`` so the lowered HLO carries the
true FLOPs; ``lax.scan`` otherwise).

The projections route through ``dense()`` and therefore inherit the FP8 /
2:4 techniques; the recurrence itself stays f32 (DESIGN.md §4: FP8 state
accumulation diverges — documented arch-applicability limit).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import RuntimeCfg, DEFAULT_RT, dense, opt_barrier, _init


def _conv1d_causal(x: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv, width W. x: (B, S, C); w: (W, C).

    With ``state`` (B, W-1, C) (decode), uses and returns updated state.
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return out, new_state


def _ssd_chunk(xh, dt, dA_cumsum, B, C, h_prev):
    """One chunk of SSD.

    xh: (b, Lc, nh, hp)   — input heads
    dt: (b, Lc, nh)       — discretization steps (post-softplus)
    dA_cumsum: (b, Lc, nh) — cumulative sum of dt*A within the chunk
    B, C: (b, Lc, N)
    h_prev: (b, nh, hp, N)
    Returns (y (b, Lc, nh, hp), h_next).
    """
    b, Lc, nh, hp = xh.shape
    # decay from chunk start to t: exp(dA_cumsum[t])
    decay_to_t = jnp.exp(dA_cumsum)                              # (b,Lc,nh)
    # inter-chunk contribution: y_inter[t] = C_t · (h_prev · decay(start..t))
    y_inter = jnp.einsum("bln,bhpn,blh->blhp", C, h_prev, decay_to_t)
    # intra-chunk: L[t,s] = exp(dA_cumsum[t]-dA_cumsum[s]) for s<=t
    seg = dA_cumsum[:, :, None, :] - dA_cumsum[:, None, :, :]    # (b,t,s,nh)
    causal = jnp.tril(jnp.ones((Lc, Lc), bool))
    L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
    # scores[t,s] = C_t·B_s ; y_intra[t] = sum_s L[t,s]*scores[t,s]*dt_s*x_s
    scores = jnp.einsum("bln,bmn->blm", C, B)                    # (b,t,s)
    G = scores[:, :, :, None] * L                                # (b,t,s,nh)
    y_intra = jnp.einsum("blsh,bsh,bshp->blhp", G, dt, xh)
    # state update: h_next = h_prev*decay(chunk) + sum_s decay(s..end)*dt_s*x_s⊗B_s
    total = dA_cumsum[:, -1:, :]                                 # (b,1,nh)
    decay_from_s = jnp.exp(total - dA_cumsum)                    # (b,Lc,nh)
    h_next = (h_prev * jnp.exp(total)[:, 0, :, None, None]
              + jnp.einsum("blh,blh,blhp,bln->bhpn",
                           decay_from_s, dt, xh, B))
    return y_intra + y_inter, h_next


def mamba2_block(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                 rt: RuntimeCfg = DEFAULT_RT) -> jax.Array:
    """Full Mamba2 mixer. x: (B, S, d) -> (B, S, d)."""
    out, _ = _mamba2_block_impl(x, p, cfg, rt)
    return out


def mamba2_block_with_state(x: jax.Array, p: Dict[str, jax.Array],
                            cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT):
    """Prefill variant: returns (out, (ssm_state, conv_state))."""
    return _mamba2_block_impl(x, p, cfg, rt)


def _mamba2_block_impl(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                       rt: RuntimeCfg = DEFAULT_RT):
    b, s, d = x.shape
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    nh, hp = cfg.ssm_nheads, cfg.ssm_head_dim

    z = dense(x, p["w_z"], cfg, rt, "ssm_z")
    xr = dense(x, p["w_x"], cfg, rt, "ssm_x")
    B_ = dense(x, p["w_B"], cfg, rt, "ssm_B")
    C_ = dense(x, p["w_C"], cfg, rt, "ssm_C")
    dt = dense(x, p["w_dt"], cfg, rt, "ssm_dt")
    conv_in = jnp.concatenate([xr, B_, C_], -1)
    final_conv_state = conv_in[:, -3:, :].astype(jnp.float32)
    xbc, _ = _conv1d_causal(conv_in, p["conv_w"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xr, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (nh,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    dA = dt * A                                                  # (B,S,nh)

    xh = xr.reshape(b, s, nh, hp)
    Lc = min(rt.ssm_chunk, cfg.ssm_chunk, s)
    assert s % Lc == 0, (s, Lc)
    nchunks = s // Lc

    def chunk_args(i):
        sl = slice(i * Lc, (i + 1) * Lc)
        dA_c = dA[:, sl]
        return (xh[:, sl], dt[:, sl], jnp.cumsum(dA_c, axis=1),
                B_[:, sl], C_[:, sl])

    h = jnp.zeros((b, nh, hp, N), jnp.float32)
    if rt.static_loops and nchunks <= rt.max_static_chunks:
        ys = []
        for i in range(nchunks):
            xh_i, dt_i, cum_i, B_i, C_i = chunk_args(i)
            if i:
                # bound liveness: sequence chunk temporaries behind the
                # state carry (see attention.py for rationale)
                xh_i, dt_i, cum_i, B_i, C_i, h = opt_barrier(
                    (xh_i, dt_i, cum_i, B_i, C_i, h))
            yi, h = _ssd_chunk(xh_i, dt_i, cum_i, B_i, C_i, h)
            ys.append(yi)
        y = jnp.concatenate(ys, axis=1)
    else:
        xh_c = xh.reshape(b, nchunks, Lc, nh, hp).transpose(1, 0, 2, 3, 4)
        dt_c = dt.reshape(b, nchunks, Lc, nh).transpose(1, 0, 2, 3)
        dA_c = dA.reshape(b, nchunks, Lc, nh).transpose(1, 0, 2, 3)
        B_c = B_.reshape(b, nchunks, Lc, N).transpose(1, 0, 2, 3)
        C_c = C_.reshape(b, nchunks, Lc, N).transpose(1, 0, 2, 3)

        def body(h, args):
            xh_i, dt_i, dA_i, B_i, C_i = args
            yi, h = _ssd_chunk(xh_i, dt_i, jnp.cumsum(dA_i, axis=1), B_i, C_i, h)
            return h, yi
        # remat: recompute the O(Lc^2) intra-chunk temps in backward
        body = jax.checkpoint(body)
        h, ys = jax.lax.scan(body, h, (xh_c, dt_c, dA_c, B_c, C_c))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hp)

    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))                   # gate
    out = dense(y.astype(x.dtype), p["out_proj"], cfg, rt, "ssm_out")
    return out, (h, final_conv_state)


def mamba2_decode(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                  state: Tuple[jax.Array, jax.Array],
                  rt: RuntimeCfg = DEFAULT_RT):
    """Single-token step. x: (B, 1, d); state = (ssm (B,nh,hp,N) f32,
    conv (B, 3, di+2N)). Returns (out, new_state)."""
    b = x.shape[0]
    di, N = cfg.ssm_d_inner, cfg.ssm_state
    nh, hp = cfg.ssm_nheads, cfg.ssm_head_dim
    h, conv_state = state

    z = dense(x, p["w_z"], cfg, rt, "ssm_z")
    xr = dense(x, p["w_x"], cfg, rt, "ssm_x")
    B_ = dense(x, p["w_B"], cfg, rt, "ssm_B")
    C_ = dense(x, p["w_C"], cfg, rt, "ssm_C")
    dt = dense(x, p["w_dt"], cfg, rt, "ssm_dt")
    xbc, conv_state = _conv1d_causal(
        jnp.concatenate([xr, B_, C_], -1), p["conv_w"], state=conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xr, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    dA = jnp.exp(dt * A)                                               # (B,nh)
    xh = xr.reshape(b, nh, hp)
    Bv, Cv = B_[:, 0], C_[:, 0]                                        # (B,N)
    h = h * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    out = dense(y.astype(x.dtype), p["out_proj"], cfg, rt, "ssm_out")
    return out, (h, conv_state)


def init_mamba2(key, cfg: ArchConfig, dtype):
    d, di, N, nh = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_nheads
    ks = jax.random.split(key, 7)
    conv_dim = di + 2 * N
    return {
        "w_z": _init(ks[0], (d, di), dtype),
        "w_x": _init(ks[1], (d, di), dtype),
        "w_B": _init(ks[2], (d, N), dtype),
        "w_C": _init(ks[3], (d, N), dtype),
        "w_dt": _init(ks[4], (d, nh), dtype),
        "conv_w": _init(ks[5], (4, conv_dim), jnp.float32, scale=0.5),
        "A_log": jnp.zeros((nh,), jnp.float32),                 # A = -1
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": _init(ks[6], (di, d), dtype),
    }


def init_mamba2_state(batch: int, cfg: ArchConfig):
    nh, hp, N = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * N
    return (jnp.zeros((batch, nh, hp, N), jnp.float32),
            jnp.zeros((batch, 3, conv_dim), jnp.float32))
