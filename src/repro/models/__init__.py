from repro.models.layers import RuntimeCfg, DEFAULT_RT, PackedWeight, dense
from repro.models.transformer import (
    forward, prefill, decode_step, init_params, params_shape, init_cache,
    cache_shape, paged_decode_step, init_paged_cache, PAGED_KINDS,
    multi_decode_step, paged_multi_decode_step,
)

__all__ = [
    "RuntimeCfg", "DEFAULT_RT", "PackedWeight", "dense", "forward", "prefill",
    "decode_step", "init_params", "params_shape", "init_cache", "cache_shape",
    "paged_decode_step", "init_paged_cache", "PAGED_KINDS",
    "multi_decode_step", "paged_multi_decode_step",
]
