"""Attention: chunked (flash-style) training/prefill path + decode path.

The chunked implementation is the pure-jnp reference for the Pallas flash
kernel (kernels/flash_attention.py) and is what the dry-run lowers: blocked
online softmax, causal or sliding-window, GQA via KV broadcast. Fully-masked
(q, kv) block pairs are *skipped at trace time* (python loop bounds), so the
lowered HLO carries only the ~triangular FLOPs — this keeps the roofline
honest and matches what the TPU kernel does.

Memory: each block is wrapped in ``jax.checkpoint`` so AD saves only block
inputs (O(S·d) residuals), the flash recompute strategy.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    RuntimeCfg, DEFAULT_RT, apply_rope, dense, opt_barrier, shard_tag)

NEG_INF = -1e30


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, kv, hd) -> (B, S, h, hd) by broadcast (GQA)."""
    b, s, kv, hd = k.shape
    if kv == num_heads:
        return k
    groups = num_heads // kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd))
    return k.reshape(b, s, num_heads, hd)


def _attn_block(q, k, v, qpos0, kpos0, *, causal, window, scale):
    """One (q-chunk, kv-chunk) block: returns (scores_max, exp_sums, acc).

    q: (B, cq, h, hd); k/v: (B, ck, h, hd). Online-softmax partials.
    """
    cq, ck = q.shape[1], k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = qpos0 + jnp.arange(cq)
    ki = kpos0 + jnp.arange(ck)
    mask = jnp.ones((cq, ck), bool)
    if causal:
        mask &= qi[:, None] >= ki[None, :]
    if window:
        mask &= (qi[:, None] - ki[None, :]) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B, h, cq)
    p = jnp.exp(s - m[..., None])
    # Fully-masked rows: m == NEG_INF -> p rows of exp(0)=1; zero them.
    p = jnp.where((m > NEG_INF / 2)[..., None], p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # (B, h, cq)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return m, l, acc


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      rt: RuntimeCfg = DEFAULT_RT,
                      q_offset: int = 0) -> jax.Array:
    """Blocked online-softmax attention.

    q: (B, Sq, h, hd); k, v: (B, Skv, kv_heads, hd). Returns (B, Sq, h, hd).
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(hd)

    cq = min(rt.chunk_q, sq)
    ck = min(rt.chunk_kv, skv)
    nq, nk = -(-sq // cq), -(-skv // ck)
    assert sq % cq == 0 and skv % ck == 0, (sq, cq, skv, ck)

    outs = []
    for i in range(nq):
        qi = jax.lax.slice_in_dim(q, i * cq, (i + 1) * cq, axis=1)
        qpos0 = q_offset + i * cq
        # kv block range that can contribute to this q chunk
        j_hi = nk if not causal else min(nk, (qpos0 + cq + ck - 1) // ck)
        j_lo = 0
        if window:
            j_lo = max(0, (qpos0 - window) // ck)
        m = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, h, cq), jnp.float32)
        acc = jnp.zeros((b, h, cq, hd), jnp.float32)

        def combine(carry, bm, bl, bacc):
            m, l, acc = carry
            m_new = jnp.maximum(m, bm)
            c1 = jnp.exp(m - m_new)
            c2 = jnp.exp(bm - m_new)
            l = l * c1 + bl * c2
            acc = acc * c1[..., None] + bacc * c2[..., None]
            return m_new, l, acc

        if rt.static_loops:
            # python loop: every block explicit in HLO — exact cost analysis
            for j in range(j_lo, j_hi):
                kj = jax.lax.slice_in_dim(k, j * ck, (j + 1) * ck, axis=1)
                vj = jax.lax.slice_in_dim(v, j * ck, (j + 1) * ck, axis=1)
                if j > j_lo:
                    # sequence the blocks behind the softmax carry so
                    # schedulers don't keep every block's scores live
                    kj, vj, m = opt_barrier((kj, vj, m))
                if rt.remat_blocks:
                    bm, bl, bacc = jax.checkpoint(
                        lambda a, bk, bv, qp=qpos0, kp=j * ck: _attn_block(
                            a, bk, bv, qp, kp, causal=causal, window=window,
                            scale=scale))(qi, kj, vj)
                else:
                    bm, bl, bacc = _attn_block(qi, kj, vj, qpos0, j * ck,
                                               causal=causal, window=window,
                                               scale=scale)
                m, l, acc = combine((m, l, acc), bm, bl, bacc)
        else:
            # lax.scan over kv blocks: one block body in HLO — bounded
            # liveness (the memory-probe lowering; see launch/dryrun.py)
            nb = j_hi - j_lo
            ks = k[:, j_lo * ck:j_hi * ck].reshape(b, nb, ck, h, hd)
            vs = v[:, j_lo * ck:j_hi * ck].reshape(b, nb, ck, h, hd)
            ks = jnp.moveaxis(ks, 1, 0)
            vs = jnp.moveaxis(vs, 1, 0)
            jidx = jnp.arange(j_lo, j_hi)

            def body(carry, inp):
                kj, vj, j = inp
                bm, bl, bacc = _attn_block(qi, kj, vj, qpos0, j * ck,
                                           causal=causal, window=window,
                                           scale=scale)
                return combine(carry, bm, bl, bacc), None
            if rt.remat_blocks:
                body = jax.checkpoint(body)
            (m, l, acc), _ = jax.lax.scan(body, (m, l, acc), (ks, vs, jidx))

        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B, h, cq, hd)
        outs.append(out.transpose(0, 2, 1, 3))                # (B, cq, h, hd)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, 1, h, hd); caches: (B, Smax, kv, hd); ``cache_len`` scalar/array —
    number of valid cache positions (the new token's k/v already written).
    """
    b, _, h, hd = q.shape
    smax = k_cache.shape[1]
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale              # (B, h, 1, Smax)
    pos = jnp.arange(smax)
    valid = pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    if window:
        valid &= pos[None, :] >= (jnp.asarray(cache_len).reshape(-1, 1) - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (pre-norm residual), used by transformer.py
# ---------------------------------------------------------------------------

def attention_block(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                    rt: RuntimeCfg = DEFAULT_RT, *, window: int = 0,
                    positions: Optional[jax.Array] = None,
                    return_kv: bool = False):
    """Projections + RoPE + chunked attention. x: (B, S, d)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)
    q = dense(x, p["w_q"], cfg, rt, "q").reshape(b, s, h, hd)
    k = dense(x, p["w_k"], cfg, rt, "k").reshape(b, s, kv, hd)
    v = dense(x, p["w_v"], cfg, rt, "v").reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_tag(rt, q, "attn_q")
    if rt.use_pallas and not window:
        from repro.kernels import ops
        o = ops.flash_attention(q, k, v, causal=True)
    else:
        o = chunked_attention(q, k, v, causal=True, window=window, rt=rt)
    o = o.reshape(b, s, h * hd)
    out = dense(o, p["w_o"], cfg, rt, "o")
    if return_kv:
        return out, (k, v)
    return out


def decode_attention_block(x: jax.Array, p: Dict[str, jax.Array],
                           cfg: ArchConfig, cache: Tuple[jax.Array, jax.Array],
                           pos, rt: RuntimeCfg = DEFAULT_RT, *,
                           window: int = 0):
    """One-token attention block with cache update.

    x: (B, 1, d); cache: (k, v) each (B, Smax, kv, hd); pos: scalar int —
    index to write the new token's k/v. Returns (out, new_cache).
    """
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k_cache, v_cache = cache
    positions = jnp.full((1,), pos)
    q = dense(x, p["w_q"], cfg, rt, "q").reshape(b, 1, h, hd)
    k = dense(x, p["w_k"], cfg, rt, "k").reshape(b, 1, kv, hd)
    v = dense(x, p["w_v"], cfg, rt, "v").reshape(b, 1, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    o = o.reshape(b, 1, h * hd)
    out = dense(o, p["w_o"], cfg, rt, "o")
    return out, (k_cache, v_cache)
